"""Hot/warm/cold residency tiers (int8 hot slots + int4 warm slots).

Three layers of guarantees:

  * tier-transition invariants — under arbitrary interleavings of
    record-usage / promote / demote / evict / pin operations (hypothesis
    property tests where available, plus deterministic seeded
    interleavings that always run): a resident expert occupies exactly
    one slot in exactly one tier, the resident + free slot sets partition
    each tier's slot space, per-tier byte accounting never exceeds the
    budget, and pinned / in-flight experts never move between tiers;

  * format contracts — promotion re-uploads the host int8 master
    (quantized from the f32 original — NEVER an int4 -> int8 upcast) and
    demotion re-uploads the host int4 master (never a transcode of the
    int8 slot), for both the sync commit path and the async prefetch
    pipeline;

  * serving differentials — a fully-resident tiered engine tracks the
    fp-resident engine within the documented int4 tolerance
    (REL_TOL_TIERED: the warm tier's per-group int4 error, ~2x int8's,
    compounds through the layer stack); and the DEGENERATE all-hot tier
    config (tier_split=1.0 -> S4=0) is byte-identical to the plain
    quantized-slot serving path across sync/async prefetch, speculative
    decode, and EP=2 sharding (mirroring tests/test_paged_kv.py's
    matrix) — the tiered store must publish an identical params tree and
    take identical bookkeeping paths when the warm tier is empty.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from repro.configs.base import TierConfig, get_config
from repro.core.engine import SiDAEngine
from repro.core.hash_fn import init_hash_fn
from repro.core.hash_table import HashTable
from repro.core.offload import (
    EXPERT_TENSORS,
    ExpertStore,
    PrefetchPipeline,
    expert_format_bytes,
    quantize_expert_q4,
)
from repro.core.residency import ResidencyManager
from repro.models.transformer import init_params, n_moe_layers
from repro.serving import Request, RequestServer

# documented serving tolerance for a warm (int4, group-scaled) resident
# set vs fp residency: per-element weight error is bounded by the group
# absmax / 14, which compounds to ~6-10% max logit deviation through the
# reduced stacks (see test_quantized.test_expert_ffn_q4_close_to_fp for
# the single-layer budget; int8 residency holds < 2e-2 on the same probe)
REL_TOL_TIERED = 0.15


def needs_devices(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs {n} simulated devices "
               f"(XLA_FLAGS=--xla_force_host_platform_device_count=4 "
               f"+ REPRO_MULTI_DEVICE_TESTS=1)",
    )


@pytest.fixture(scope="module")
def tiny():
    """2-layer miniature with capacity_factor high enough that MoE token
    capacity never binds — the regime where residency differentials are
    exact (dispatch capacity scales with the combined slot count)."""
    cfg = get_config("switch-base-8").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=2,
        moe=dataclasses.replace(cfg.moe, capacity_factor=100.0),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    hp = init_hash_fn(
        jax.random.PRNGKey(1), cfg.d_model, n_moe_layers(cfg),
        cfg.moe.num_experts, d_h=16, draft=True,
    )
    return cfg, params, hp


def _tier(**kw):
    kw.setdefault("int4_slots", True)
    return TierConfig(**kw)


def _store(tiny_or_pair, slots=1, warm=2, eviction="lru", **tkw):
    cfg, params = tiny_or_pair[0], tiny_or_pair[1]
    return ExpertStore(
        cfg, params, slots_per_layer=slots, eviction=eviction,
        quantized_slots=True, tier=_tier(warm_slots=warm, **tkw),
    )


def _table(L, E, needed, step=0):
    """A hash table routing one token to each expert in `needed`."""
    ids = np.asarray(needed, np.int64).reshape(1, 1, -1, 1)
    ids = np.broadcast_to(ids, (L,) + ids.shape[1:])
    w = np.ones(ids.shape, np.float32)
    return HashTable(step, ids, w)


def check_tier_invariants(st):
    """The tier-transition safety net: called after every operation in the
    interleaving suites."""
    b = st.tier_slot_bytes()
    budget = st.S8 * b["hot"] + st.S4 * b["warm"]
    for key, res in st.resident.items():
        slots = list(res.values())
        # a resident expert occupies exactly ONE slot (so exactly one tier)
        assert len(slots) == len(set(slots)), f"slot double-booked: {res}"
        hot = [sl for sl in slots if sl < st.S8]
        warm = [sl for sl in slots if sl >= st.S8]
        assert all(0 <= sl < st.S8 for sl in hot)
        assert all(st.S8 <= sl < st.S8 + st.S4 for sl in warm)
        # per-tier byte accounting sums to (at most) the configured budget
        used = len(hot) * b["hot"] + len(warm) * b["warm"]
        assert used <= budget, (used, budget)
        # resident + free partitions each tier's slot space exactly
        free_h = {x for m in range(st.shards) for x in st.free[key][m]}
        free_w = {x for m in range(st.shards) for x in st.free4[key][m]}
        rep = {sl for by in st.replicas[key].values() for sl in by.values()}
        occupied = set(slots) | rep
        assert not occupied & free_h and not occupied & free_w
        assert occupied | free_h | free_w == set(range(st.S))
        # every slot is owned by the shard its id partition says it is
        for sl in slots:
            assert 0 <= st.slot_shard(sl) < st.shards
            assert st.slot_tier(sl) == ("warm" if sl >= st.S8 else "hot")


def _tiers_of(st, layer=0):
    g, s = st.layer_to_gs(layer)
    return {
        e: st.slot_tier(sl) for e, sl in st.resident[(g, s)].items()
    }


# ---------------------------------------------------------------------------
# geometry + byte accounting
# ---------------------------------------------------------------------------


def test_tier_geometry_and_slot_space(tiny):
    st = _store(tiny, slots=2, warm=2)
    assert st.tiered and st.S8 == 2 and st.S4 == 2 and st.S == 4
    assert st.slot_tier(0) == "hot" and st.slot_tier(1) == "hot"
    assert st.slot_tier(2) == "warm" and st.slot_tier(3) == "warm"
    assert st.slot_shard(3) == 0
    trans = np.array([[0, 2, -1, 3]], np.int32)
    local = st.local_trans(trans)
    # warm global ids map past the shard's hot partition: S8_loc + offset
    np.testing.assert_array_equal(local, [[0, 2, -1, 3]])


def test_tier_split_caps_at_num_experts(tiny):
    """Combined slots never exceed E: extra slots would shrink dispatch
    capacity (C ~ tokens / n_slots) below the dense forward's and drop
    tokens the untiered store serves."""
    cfg = tiny[0]
    E = cfg.moe.num_experts
    st = _store(tiny, slots=E, warm=2 * E)
    assert st.S8 == E and st.S4 == 0
    st = ExpertStore(
        tiny[0], tiny[1], slots_per_layer=4 * E, quantized_slots=True,
        tier=_tier(tier_split=0.5),
    )
    assert st.S8 + st.S4 <= E


def test_tier_format_bytes_and_capacity_ratio(tiny):
    """expert_format_bytes is the single byte rule: int4 (nibble slab +
    per-group scale plane) buys >= 1.8x the experts of int8 at equal
    bytes, and tier_slot_bytes/device_bytes agree with it."""
    st = _store(tiny, slots=1, warm=1)
    shapes = st._expert_shapes
    b8 = expert_format_bytes(shapes, "int8")
    b4 = expert_format_bytes(shapes, "int4", st.tier.group_size)
    assert b8 / b4 >= 1.8, b8 / b4
    tb = st.tier_slot_bytes()
    assert tb == {"hot": b8, "warm": b4}
    # the published pools cost exactly S8 hot + S4 warm slots per layer
    per_layer = st.device_bytes() // len(st.moe_subs)
    assert per_layer == st.S8 * b8 + st.S4 * b4


def test_split_budget_tiered():
    hot, warm, pages = ResidencyManager.split_budget_tiered(
        100_000, hot_slot_bytes=1000, warm_slot_bytes=550, page_bytes=100,
        n_moe_layers=2, tier_split=0.5,
    )
    assert hot >= 1 and warm >= 1 and pages >= 1
    # the tiered split never exceeds the untiered expert byte budget
    slots, pages0 = ResidencyManager.split_budget(100_000, 1000, 100, 2)
    assert hot * 1000 + warm * 550 <= slots * 1000
    assert pages == pages0
    # all-hot degenerates to the untiered split
    h1, w1, _ = ResidencyManager.split_budget_tiered(
        100_000, 1000, 550, 100, 2, tier_split=1.0,
    )
    assert (h1, w1) == (slots, 0)


def test_tiered_store_scope_gates(tiny):
    cfg, params = tiny[0], tiny[1]
    with pytest.raises(AssertionError):
        ExpertStore(cfg, params, slots_per_layer=2, tier=_tier())  # no int8
    st = _store(tiny, slots=2, warm=2)
    assert st.rebalance_homes() == 0  # rebalancing is out of tier scope


# ---------------------------------------------------------------------------
# tier transitions: demote / promote / overflow
# ---------------------------------------------------------------------------


def test_miss_pressure_demotes_hot_victim_to_warm(tiny):
    """A hot-tier miss with no free hot slot demotes the policy victim to
    a warm slot instead of evicting to host: the expert SURVIVES resident
    (int4) and the byte accounting moves one slot between tiers."""
    st = _store(tiny, slots=1, warm=1)
    st.prepare(_table(st.L, st.E, [0]))
    assert _tiers_of(st) == {0: "hot"}
    st.prepare(_table(st.L, st.E, [1], step=1))
    tiers = _tiers_of(st)
    assert tiers[1] == "hot" and tiers[0] == "warm"  # demoted, not evicted
    assert st.stats.demotions >= 1 and st.stats.evictions == 0
    check_tier_invariants(st)


def test_warm_hit_promotes_into_free_hot_slot(tiny):
    st = _store(tiny, slots=2, warm=2)
    st.prepare(_table(st.L, st.E, [0, 1]))
    # force 0 into warm by demand-loading 2 and 3 over a full hot tier
    st.prepare(_table(st.L, st.E, [2, 3], step=1))
    tiers = _tiers_of(st)
    warm_e = [e for e, t in tiers.items() if t == "warm"]
    assert warm_e, tiers
    # evict a hot resident to free a hot slot, then hit the warm expert
    g, s = st.layer_to_gs(0)
    hot_e = [e for e, t in _tiers_of(st).items() if t == "hot"]
    victim_slot = st.resident[(g, s)].pop(hot_e[0])
    st.free[(g, s)][0].append(victim_slot)
    st.policy[(g, s)][0].forget(hot_e[0])
    before = st.stats.promotions
    st.prepare(_table(st.L, st.E, [warm_e[0]], step=2))
    assert st.stats.promotions > before
    assert _tiers_of(st)[warm_e[0]] == "hot"
    check_tier_invariants(st)


def test_warm_hit_swaps_with_cold_hot_resident_by_alpha(tiny):
    """Promotion hysteresis: a warm resident swaps tiers with the coldest
    hot resident only when its decayed α mass beats the victim's by
    promote_margin — the victim demotes into the promoted expert's old
    warm slot (no capacity created or destroyed)."""
    st = _store(tiny, slots=2, warm=2, promote_margin=1.25,
                eviction="alpha")
    g, s = st.layer_to_gs(0)

    def mass(weights):
        m = np.zeros(st.E, np.float64)
        for e, w in weights.items():
            m[e] = w
        return m

    # 0,1 hot; then 2 loads over pressure -> demotes the α-coldest (1)
    st.plan_layer(0, np.array([0, 1]), mass=mass({0: 1.0, 1: 0.01}))
    st.plan_layer(0, np.array([2]), mass=mass({2: 0.02}))
    tiers = _tiers_of(st)
    assert tiers == {0: "hot", 2: "hot", 1: "warm"}
    # a few heavy hits on warm-resident 1 push its EMA over the margin
    before = st.stats.promotions
    for _ in range(4):
        st.plan_layer(0, np.array([1]), mass=mass({1: 1.0}))
        check_tier_invariants(st)
    tiers = _tiers_of(st)
    assert st.stats.promotions > before
    assert tiers[1] == "hot" and tiers[2] == "warm"  # swapped, both resident
    assert len(tiers) == 3


def test_hot_tier_full_of_protected_overflows_to_warm(tiny):
    """When every hot slot is protected (all needed this step), the next
    needed expert loads straight into a warm slot instead of dropping —
    the combined capacity S8 + S4 is reachable in ONE plan call."""
    st = _store(tiny, slots=1, warm=2)
    st.prepare(_table(st.L, st.E, [0, 1, 2]))
    tiers = _tiers_of(st)
    assert len(tiers) == 3 and st.stats.dropped == 0
    assert sorted(t for t in tiers.values()) == ["hot", "warm", "warm"]
    check_tier_invariants(st)


def test_pinned_expert_never_demotes(tiny):
    st = _store(tiny, slots=1, warm=2)
    st.prepare(_table(st.L, st.E, [0]))
    st.pin_experts(0, [0])
    for step, e in enumerate([1, 2, 3, 1, 2]):
        st.prepare(_table(st.L, st.E, [e], step=step + 1))
        assert _tiers_of(st)[0] == "hot", "pinned expert left the hot tier"
        check_tier_invariants(st)


def test_inflight_protected_expert_never_moves(tiny):
    """Experts protected by an unreleased ticket / in-flight upload
    (extra_protected) must not change slots: a pending forward's
    translation may point at the current slot."""
    st = _store(tiny, slots=1, warm=2)
    st.prepare(_table(st.L, st.E, [0]))
    g, s = st.layer_to_gs(0)
    slot0 = st.resident[(g, s)][0]
    # plan a miss while 0 is extra-protected: 0 must keep its exact slot
    for l in range(st.L):
        st.plan_layer(l, np.array([1]), extra_protected={0})
    assert st.resident[(g, s)][0] == slot0
    check_tier_invariants(st)


# ---------------------------------------------------------------------------
# format contracts: master re-quantization, never transcode
# ---------------------------------------------------------------------------


def test_warm_rows_are_host_int4_masters(tiny):
    """Demotion re-uploads the host int4 master rows (quantized from the
    f32 originals) — never a transcode of the int8 slot contents."""
    cfg, params = tiny[0], tiny[1]
    st = _store(tiny, slots=1, warm=2)
    st.prepare(_table(st.L, st.E, [0]))
    st.prepare(_table(st.L, st.E, [1], step=1))  # demotes 0 to warm
    g, s = st.layer_to_gs(0)
    wslot = st.resident[(g, s)][0]
    assert wslot >= st.S8
    moe_p = st.serve_params["blocks"][f"sub{s}"]["moe"]
    for t in EXPERT_TENSORS:
        np.testing.assert_array_equal(
            np.asarray(moe_p[t + "_q4"][g, wslot - st.S8]),
            st.host4[f"sub{s}"][t][g, 0],
        )
        np.testing.assert_array_equal(
            np.asarray(moe_p[t + "_q4_scale"][g, wslot - st.S8]),
            st.host4_scale[f"sub{s}"][t][g, 0],
        )
        # and the master IS the f32 original quantized to int4
        q_ref, s_ref = quantize_expert_q4(
            np.asarray(params["blocks"][f"sub{s}"]["moe"][t]),
            st.tier.group_size,
        )
        np.testing.assert_array_equal(
            st.host4[f"sub{s}"][t][g, 0], q_ref[g, 0]
        )
        np.testing.assert_array_equal(
            st.host4_scale[f"sub{s}"][t][g, 0], s_ref[g, 0]
        )


def test_promotion_requantizes_from_f32_master_not_upcast(tiny):
    """After a warm -> hot promotion the hot slot holds the host int8
    master rows EXACTLY (int8 quantized from f32) — an int4 -> int8
    upcast would differ wherever the int4 round-trip lost precision."""
    st = _store(tiny, slots=1, warm=2)
    st.prepare(_table(st.L, st.E, [0]))
    st.prepare(_table(st.L, st.E, [1], step=1))       # 0 demoted to warm
    g, s = st.layer_to_gs(0)
    # free the hot tier so the warm hit promotes without a swap
    st.resident[(g, s)].pop(1)
    st.free[(g, s)][0].append(0)
    st.policy[(g, s)][0].forget(1)
    st.prepare(_table(st.L, st.E, [0], step=2))        # promote 0
    slot = st.resident[(g, s)][0]
    assert slot < st.S8 and st.stats.promotions >= 1
    moe_p = st.serve_params["blocks"][f"sub{s}"]["moe"]
    for t in EXPERT_TENSORS:
        np.testing.assert_array_equal(
            np.asarray(moe_p[t][g, slot]), st.host[f"sub{s}"][t][g, 0]
        )
    check_tier_invariants(st)


def test_async_pipeline_stages_warm_and_hot_slabs(tiny):
    """The prefetch pipeline splits each upload batch by destination tier
    — int8 masters into the hot pools, int4 masters + group scales into
    the q4 pools — and the ready fence fires only after BOTH commits."""
    st = _store(tiny, slots=1, warm=2)
    with PrefetchPipeline(st, depth=2) as pf:
        t0 = pf.submit(_table(st.L, st.E, [0, 1, 2]))
        assert t0.wait(timeout=30.0)
        g, s = st.layer_to_gs(0)
        moe_p = st.serve_params["blocks"][f"sub{s}"]["moe"]
        tiers = _tiers_of(st)
        assert sorted(tiers.values()) == ["hot", "warm", "warm"]
        for e, tier in tiers.items():
            slot = st.resident[(g, s)][e]
            if tier == "hot":
                np.testing.assert_array_equal(
                    np.asarray(moe_p["w_in"][g, slot]),
                    st.host[f"sub{s}"]["w_in"][g, e],
                )
            else:
                np.testing.assert_array_equal(
                    np.asarray(moe_p["w_in_q4"][g, slot - st.S8]),
                    st.host4[f"sub{s}"]["w_in"][g, e],
                )
        t0.release()
        check_tier_invariants(st)


# ---------------------------------------------------------------------------
# interleaving suites: deterministic + hypothesis
# ---------------------------------------------------------------------------


def _drive(st, ops):
    """Apply an op sequence to layer 0; invariants checked after each.

    Pinned experts may legitimately sit in EITHER tier (pinning freezes,
    it does not promote, and an all-protected hot tier overflow-loads a
    pinned miss into warm) — the invariant is that a pinned HOT resident
    never demotes while it stays pinned."""
    protected_pool = set()
    g, s = st.layer_to_gs(0)

    def pinned_hot():
        return {
            pe for pe in st.pinned[(g, s)]
            if pe in st.resident[(g, s)]
            and st.slot_tier(st.resident[(g, s)][pe]) == "hot"
        }

    for kind, e in ops:
        e = e % st.E
        frozen = pinned_hot()
        if kind == "pin":
            st.pin_experts(0, [e])
        elif kind == "unpin":
            st.unpin_experts(0, [e])
        elif kind == "protected":
            protected_pool = {e}
        else:  # "use": route mass to e (record_usage + plan transitions)
            m = np.zeros(st.E, np.float64)
            m[e] = 1.0
            st.plan_layer(0, np.array([e]), mass=m,
                          extra_protected=protected_pool or None)
        check_tier_invariants(st)
        for pe in frozen & st.pinned[(g, s)]:
            assert pe in st.resident[(g, s)], "pinned hot expert evicted"
            assert st.slot_tier(st.resident[(g, s)][pe]) == "hot", \
                "pinned hot expert demoted"


@pytest.mark.parametrize("seed", range(6))
def test_deterministic_interleavings(tiny, seed):
    """Seeded random interleavings of use/pin/unpin/protect ops (always
    runs, no hypothesis dependency): every intermediate state satisfies
    the tier invariants and pinned experts never leave the hot tier."""
    rng = np.random.default_rng(seed)
    st = _store(tiny, slots=rng.integers(1, 3), warm=rng.integers(1, 3),
                eviction=["lru", "fifo", "alpha"][seed % 3])
    kinds = ["use", "use", "use", "use", "pin", "unpin", "protected"]
    ops = [(kinds[rng.integers(len(kinds))], int(rng.integers(0, st.E)))
           for _ in range(40)]
    _drive(st, ops)


try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:  # CI installs hypothesis; local runs still cover above
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    ops_strategy = hst.lists(
        hst.tuples(
            hst.sampled_from(["use", "use", "use", "pin", "unpin",
                              "protected"]),
            hst.integers(0, 7),
        ),
        min_size=1, max_size=50,
    )

    @settings(max_examples=60, deadline=None)
    @given(ops=ops_strategy, s8=hst.integers(1, 2), s4=hst.integers(1, 2),
           eviction=hst.sampled_from(["lru", "fifo", "alpha"]))
    def test_tier_invariants_under_arbitrary_interleavings(
        tier_system, ops, s8, s4, eviction
    ):
        """Property: arbitrary op interleavings preserve every tier
        invariant (one tier per resident, exact slot-space partition,
        byte budget, pinned immobility)."""
        st = _store(tier_system, slots=s8, warm=s4, eviction=eviction)
        _drive(st, ops)

    @pytest.fixture(scope="module")
    def tier_system():
        """Module-scope (cfg, params) shared across hypothesis examples —
        building an ExpertStore per example is cheap; init_params is not."""
        cfg = get_config("switch-base-8").reduced()
        cfg = dataclasses.replace(cfg, n_layers=2)
        return cfg, init_params(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# serving differentials
# ---------------------------------------------------------------------------


def _reqs(cfg, seed, n=5):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(4, 16)),)).astype(np.int32),
            max_new_tokens=int(rng.integers(3, 8)),
        )
        for i in range(n)
    ]


def _serve(tiny, reqs, ep_shards=1, **kw):
    cfg, params, hp = tiny
    if ep_shards > 1:
        from repro.core.offload import ShardedStoreConfig
        from repro.launch.mesh import make_ep_mesh
        from repro.sharding.policy import serve_ctx

        kw["ctx"] = serve_ctx(make_ep_mesh(ep_shards))
        kw["sharded"] = ShardedStoreConfig(ep_shards=ep_shards)
    kw.setdefault("buckets", (8, 16))
    kw.setdefault("cache_len", 32)
    kw.setdefault("quantized_slots", True)
    srv = RequestServer(
        cfg, params, hp, max_lanes=3, max_prefill_batch=3, **kw,
    )
    srv.run(reqs, realtime=False)
    srv.close()
    return srv


def _gen(srv):
    return {r.rid: r.generated for r in srv.completed}


def test_engine_tiered_all_resident_close_to_fp(tiny):
    """A fully-resident tiered engine (hot + warm covers every expert)
    serves logits within REL_TOL_TIERED of the fp-resident engine on a
    shared token stream — the documented warm-tier accuracy budget."""
    cfg, params, hp = tiny
    E = cfg.moe.num_experts
    eng_fp = SiDAEngine(cfg, params, hp, slots_per_layer=E, eviction="lru")
    eng_t = SiDAEngine(
        cfg, params, hp, slots_per_layer=E // 2, eviction="lru",
        quantized_slots=True, tier=_tier(warm_slots=E - E // 2),
    )
    assert eng_t.store.S8 + eng_t.store.S4 == E
    rng = np.random.default_rng(0)
    worst = 0.0
    for i in range(3):
        toks = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
        lf = np.asarray(eng_fp.infer(toks, eng_fp.build_table(i, toks)))
        lt = np.asarray(eng_t.infer(toks, eng_t.build_table(i, toks)))
        worst = max(worst, float(np.abs(lf - lt).max() / np.abs(lf).max()))
    assert worst < REL_TOL_TIERED, worst
    assert eng_t.store.stats.dropped == 0
    eng_fp.close()
    eng_t.close()


def test_server_tiered_completes_with_warm_traffic(tiny):
    """The tiered request server completes a mixed stream end to end with
    live tier transitions (loads into both tiers) and no admission
    regressions vs the quantized server."""
    cfg = tiny[0]
    srv = _serve(tiny, _reqs(cfg, 3), slots_per_layer=1,
                 tier=_tier(warm_slots=2), prefetch_depth=2)
    st = srv.store.stats
    assert len(srv.completed) == 5 and not srv.rejected
    assert srv.store.S4 > 0 and st.loads > 0
    # the warm tier actually carried traffic: either a transition fired
    # (demote-on-pressure / warm-hit promote) or overflow loads landed
    # residents in warm slots (which count in neither transition stat)
    warm_resident = any(
        sl >= srv.store.S8
        for res in srv.store.resident.values()
        for sl in res.values()
    )
    assert warm_resident or st.demotions + st.promotions > 0
    check_tier_invariants(srv.store)


# --- degenerate all-hot config: byte-identical to the quantized path ----


@pytest.mark.parametrize("prefetch_depth", [0, 2])
def test_server_degenerate_tier_matches_quant(tiny, prefetch_depth):
    """tier_split=1.0 -> S4=0: the tiered server must be BYTE-IDENTICAL
    to the plain quantized-slot server, sync and async. Token identity is
    compared at full residency (like test_ep_serving's differentials —
    under slot pressure the server's lane-packing order is timing-
    dependent, so miss trajectories aren't comparable run-to-run for
    EITHER side); the structural guarantees (no q4 pools, untiered code
    path) hold regardless."""
    cfg = tiny[0]
    E = cfg.moe.num_experts
    quant = _serve(tiny, _reqs(cfg, 1), slots_per_layer=E,
                   prefetch_depth=prefetch_depth)
    tiered = _serve(tiny, _reqs(cfg, 1), slots_per_layer=E,
                    prefetch_depth=prefetch_depth,
                    tier=_tier(tier_split=1.0))
    assert tiered.store.S4 == 0 and tiered.store.S8 == E
    # S4=0 drops the tier flag entirely: every plan/commit/upload branch
    # IS the untiered quantized store's, not a parallel tier-aware copy
    assert not tiered.store.tiered
    # ...and publishes NO q4 pools: the params tree is the quantized tree
    for blk in tiered.store.serve_params["blocks"].values():
        if "moe" in blk:
            assert "w_in_q4" not in blk["moe"]
    assert _gen(quant) == _gen(tiered)
    st = tiered.store.stats
    assert st.promotions == 0 and st.demotions == 0


def test_server_degenerate_tier_spec_matches_quant(tiny):
    # all experts resident + sync uploads, like test_paged_kv's spec
    # differentials: under slot pressure the spec server's lane-packing
    # order is timing-dependent, which makes miss-trajectory token
    # comparisons flaky (for BOTH sides) without pinning residency
    cfg = tiny[0]
    kw = dict(spec_mode="draft", spec_k=3,
              slots_per_layer=cfg.moe.num_experts, prefetch_depth=0)
    quant = _serve(tiny, _reqs(cfg, 1), **kw)
    tiered = _serve(tiny, _reqs(cfg, 1), tier=_tier(tier_split=1.0), **kw)
    assert _gen(quant) == _gen(tiered)


@needs_devices(2)
@pytest.mark.parametrize("prefetch_depth", [0, 2])
def test_ep2_server_degenerate_tier_matches_quant(tiny, prefetch_depth):
    """The degenerate identity holds under EP=2 sharded serving: S4=0
    publishes no q4 pools, so the shard_map dispatch takes the untiered
    single-range path bit-for-bit."""
    cfg = tiny[0]
    quant = _serve(tiny, _reqs(cfg, 1), ep_shards=2,
                   slots_per_layer=cfg.moe.num_experts,
                   prefetch_depth=prefetch_depth)
    tiered = _serve(tiny, _reqs(cfg, 1), ep_shards=2,
                    slots_per_layer=cfg.moe.num_experts,
                    prefetch_depth=prefetch_depth,
                    tier=_tier(tier_split=1.0))
    assert _gen(quant) == _gen(tiered)


@needs_devices(2)
def test_ep2_server_tiered_matches_single_device(tiny):
    """A fully-resident TIERED working set under EP=2 produces the same
    greedy tokens as single-device tiered serving: the two-range slot
    masking in the shard_map dispatch selects exactly the shard-local
    hot + warm rows."""
    cfg = tiny[0]
    E = cfg.moe.num_experts
    kw = dict(slots_per_layer=E // 2, tier=_tier(warm_slots=E - E // 2))
    single = _serve(tiny, _reqs(cfg, 1), **kw)
    sharded = _serve(tiny, _reqs(cfg, 1), ep_shards=2, **kw)
    assert sharded.store.S4 > 0
    assert _gen(single) == _gen(sharded)
