#!/usr/bin/env python
"""Public-API snapshot check for `repro.serving` (CI step).

The serving package's public surface — `repro.serving.__all__`, the kind of
each exported symbol, every `ServingConfig`/`TenantConfig` field (sub-configs
flattened to dotted paths), and the CLI flag -> config-path table
(`SERVE_FLAGS`) — is snapshotted in tools/api_snapshot.json. CI diffs the
live surface against the snapshot, so renaming/removing an export or config
field, or silently changing a flag's destination, fails the build until the
change is made deliberately:

    python tools/check_api.py            # verify (exit 1 on drift)
    python tools/check_api.py --update   # regenerate the snapshot
"""
from __future__ import annotations

import dataclasses
import inspect
import json
import os
import sys

SNAPSHOT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "api_snapshot.json")


def symbol_kind(obj) -> str:
    if dataclasses.is_dataclass(obj) and inspect.isclass(obj):
        return "dataclass"
    if inspect.isclass(obj):
        return "class"
    if inspect.isfunction(obj):
        return "function"
    return "constant"


def config_fields(cls, prefix: str = "") -> list:
    """Flatten a config dataclass's fields to dotted paths, recursing into
    dataclass-typed sub-configs (BatchingConfig etc.) one level deep."""
    paths = []
    for f in dataclasses.fields(cls):
        sub = f.default_factory if f.default_factory is not dataclasses.MISSING else None  # noqa: E501
        if sub is not None and dataclasses.is_dataclass(sub):
            paths += config_fields(sub, prefix=f"{prefix}{f.name}.")
        else:
            paths.append(f"{prefix}{f.name}")
    return paths


def current_surface() -> dict:
    import repro.serving as serving
    from repro.serving.config import SERVE_FLAGS, ServingConfig, TenantConfig

    return {
        "all": {name: symbol_kind(getattr(serving, name))
                for name in sorted(serving.__all__)},
        "serving_config_fields": sorted(config_fields(ServingConfig)),
        "tenant_config_fields": sorted(config_fields(TenantConfig)),
        "serve_flags": {spec.flag: spec.path for spec in SERVE_FLAGS},
    }


def main(argv: list) -> int:
    surface = current_surface()
    if "--update" in argv:
        with open(SNAPSHOT, "w", encoding="utf-8") as f:
            json.dump(surface, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {SNAPSHOT}")
        return 0
    if not os.path.exists(SNAPSHOT):
        print(f"ERROR: {SNAPSHOT} missing — run `python tools/check_api.py "
              "--update` and commit it")
        return 1
    with open(SNAPSHOT, encoding="utf-8") as f:
        want = json.load(f)
    errors = []
    for section in sorted(set(want) | set(surface)):
        got_s, want_s = surface.get(section), want.get(section)
        if got_s == want_s:
            continue
        if isinstance(want_s, dict) and isinstance(got_s, dict):
            for key in sorted(set(want_s) | set(got_s)):
                if key not in got_s:
                    errors.append(f"{section}: {key!r} removed from API")
                elif key not in want_s:
                    errors.append(f"{section}: {key!r} added (not in snapshot)")
                elif got_s[key] != want_s[key]:
                    errors.append(f"{section}: {key!r} changed "
                                  f"{want_s[key]!r} -> {got_s[key]!r}")
        else:
            missing = sorted(set(want_s or []) - set(got_s or []))
            added = sorted(set(got_s or []) - set(want_s or []))
            for m in missing:
                errors.append(f"{section}: {m!r} removed from API")
            for a in added:
                errors.append(f"{section}: {a!r} added (not in snapshot)")
    for e in errors:
        print(f"ERROR: {e}")
    if errors:
        print(f"API drift vs {SNAPSHOT} ({len(errors)} change(s)); if "
              "intentional: python tools/check_api.py --update")
        return 1
    print(f"repro.serving API matches snapshot "
          f"({len(surface['all'])} exports, "
          f"{len(surface['serving_config_fields'])} config fields, "
          f"{len(surface['serve_flags'])} flags)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
