#!/usr/bin/env python
"""Offline markdown link checker for README.md / docs/ (CI step).

Checks every relative link target ([text](path), [text](path#anchor)) in
the given markdown files/directories:
  * the target file or directory must exist (relative to the linking file);
  * a #anchor into a markdown file must match one of its headings under
    GitHub's slug rules (lowercase, spaces -> dashes, punctuation dropped).
External (http/https/mailto) links are skipped — CI stays hermetic.

    python tools/check_links.py README.md docs ROADMAP.md
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    h = re.sub(r"`([^`]*)`", r"\1", heading)       # strip inline code ticks
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", h)  # links -> text
    h = h.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(md_path: str) -> set:
    text = open(md_path, encoding="utf-8").read()
    text = CODE_FENCE_RE.sub("", text)
    return {github_slug(m) for m in HEADING_RE.findall(text)}


def check_file(md_path: str) -> list:
    errors = []
    text = open(md_path, encoding="utf-8").read()
    text = CODE_FENCE_RE.sub("", text)
    base = os.path.dirname(os.path.abspath(md_path))
    for target in LINK_RE.findall(text):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, ...
            continue
        path, _, anchor = target.partition("#")
        if not path:  # same-file anchor
            if anchor and github_slug(anchor) not in anchors_of(md_path):
                errors.append(f"{md_path}: broken anchor #{anchor}")
            continue
        full = os.path.normpath(os.path.join(base, path))
        if not os.path.exists(full):
            errors.append(f"{md_path}: broken link {target!r} -> {full}")
            continue
        if anchor and full.endswith(".md"):
            if github_slug(anchor) not in anchors_of(full):
                errors.append(
                    f"{md_path}: broken anchor {target!r} (no such heading)"
                )
    return errors


def main(argv: list) -> int:
    files = []
    for arg in argv or ["README.md", "docs"]:
        if os.path.isdir(arg):
            for root, _, names in os.walk(arg):
                files += [os.path.join(root, n) for n in names
                          if n.endswith(".md")]
        else:
            files.append(arg)
    errors = []
    for f in sorted(files):
        errors += check_file(f)
    for e in errors:
        print(f"ERROR: {e}")
    print(f"checked {len(files)} file(s): "
          f"{'FAIL' if errors else 'OK'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
