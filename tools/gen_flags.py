#!/usr/bin/env python
"""Regenerate the README serve-flag table from the actual CLI (CI step).

The table between `<!-- serve-flags:begin -->` and `<!-- serve-flags:end -->`
in README.md is rendered from `repro.launch.serve.build_parser()` — which in
turn registers every serving knob from `SERVE_FLAGS` (serving/config.py). One
declaration drives argparse, `ServingConfig.from_args`, and the docs, so a
flag added or changed in code cannot drift from the README:

    PYTHONPATH=src python tools/gen_flags.py            # rewrite README.md
    PYTHONPATH=src python tools/gen_flags.py --check    # CI: exit 1 on drift
"""
from __future__ import annotations

import argparse
import os
import re
import sys

README = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                      "README.md")
BEGIN, END = "<!-- serve-flags:begin -->", "<!-- serve-flags:end -->"
MARK_RE = re.compile(re.escape(BEGIN) + r".*?" + re.escape(END), re.DOTALL)


def fmt_default(action: argparse.Action) -> str:
    if isinstance(action, (argparse._StoreTrueAction, argparse._StoreFalseAction)):
        return "off"
    if action.default in (None, ""):
        return "none"
    return f"`{action.default}`"


def render_table() -> str:
    from repro.launch.serve import build_parser

    rows = ["| flag | default | meaning |", "|---|---|---|"]
    for action in build_parser()._actions:
        if not action.option_strings or action.option_strings[0] in ("-h", "--help"):
            continue
        help_text = " ".join((action.help or "").split())
        # escape the column separator so grammar strings with | survive
        help_text = help_text.replace("|", "\\|")
        rows.append(f"| `{action.option_strings[0]}` | "
                    f"{fmt_default(action)} | {help_text} |")
    return "\n".join(rows)


def main(argv: list) -> int:
    with open(README, encoding="utf-8") as f:
        text = f.read()
    if BEGIN not in text or END not in text:
        print(f"ERROR: {README} is missing the {BEGIN} / {END} markers")
        return 1
    block = f"{BEGIN}\n{render_table()}\n{END}"
    updated = MARK_RE.sub(lambda _: block, text)
    if "--check" in argv:
        if updated != text:
            print("ERROR: README serve-flag table is stale — regenerate with "
                  "`PYTHONPATH=src python tools/gen_flags.py`")
            return 1
        print("README serve-flag table matches build_parser()")
        return 0
    if updated == text:
        print("README serve-flag table already up to date")
        return 0
    with open(README, "w", encoding="utf-8") as f:
        f.write(updated)
    print(f"rewrote serve-flag table in {README}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
